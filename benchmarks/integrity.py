"""Integrity benchmark: the checksummed wire under silent corruption.

Three experiments, recorded under the ``integrity`` section of
BENCH_kernels.json:

* ``detect`` — envelope detection is total: for every silent-corruption
  kind (sign / scale / nan), a verifying :class:`Transport` catches 100%
  of perturbed payloads at the wire (``silent_detected ==
  silent_corrupts``), every delivered array is byte-equal to the
  original, and every retransmission is billed under ``retry/<tag>`` at
  the message's exact units.  An end-to-end build through a corrupting
  verified wire lands draw-identical to the clean build, paying only the
  retry bill.
* ``quarantine`` — the acceptance gate: party 0 sign-flips its round-1
  mass table on EVERY send through an unverifying wire.  Undefended
  (``fault_policy="retry"``: envelope checks off, values trusted) the
  downstream ridge fit's rel_error blows past 3x the clean build's;
  defended (``fault_policy="quarantine"``) the validators catch the
  negative masses, drop party 0 via the degrade machinery, and the
  rebuilt coreset's rel_error stays within 3x of clean (small absolute
  floor for the both-tiny regime).  The receipt names the offender.
* ``overhead`` — checksum cost: a warm pipelined build through a null
  verifying transport (every payload sealed + digest-checked, zero
  faults) stays within 5% of the transportless build's rows/s, and is
  draw-identical to it.

  PYTHONPATH=src python -m benchmarks.integrity --fast
  PYTHONPATH=src python -m benchmarks.run --sections integrity --strict
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from benchmarks.serve import _chunk_stream, _stream_ds
from repro.core import (
    CommLedger,
    CoresetPipeline,
    CoresetSpec,
    FaultPlan,
    Transport,
)
from repro.core.faults import SILENT_KINDS
from repro.core.solve import evaluate, fit_ridge, full_data_coreset

BENCH = "integrity"
SECTION = "integrity"

DETECT_RATE = 0.4            # per-message corruption odds at the wire
DETECT_RETRIES = 16          # 0.4^17 ~ 2e-7 exhaustion odds per message
QUALITY_GATE = 3.0           # quarantined rel_error within 3x of clean
REL_FLOOR = 0.02             # both-tiny regime: absolute floor on the gate
POISON_N = 20_000            # the acceptance criterion's n
OVERHEAD_GATE = 0.05         # checksum wire within 5% of transportless
OVERHEAD_REPS = 5


def _vrlr_stream(seed, n, d=12, T=3, num_chunks=4):
    chunks = _chunk_stream(seed, num_chunks, n // num_chunks, d, T, True)
    return chunks, _stream_ds(chunks)


# --------------------------------------------------------------------------
# Experiment 1: wire-level detection is total, per corruption kind
# --------------------------------------------------------------------------

def run_detect(fast: bool):
    rounds = 100 if fast else 400
    T, cells = 3, 64
    rng = np.random.default_rng(0)
    payloads = {j: rng.random((T, cells)).astype(np.float32) + 0.1
                for j in range(T)}
    units = {j: cells for j in range(T)}

    entries, rows = [], []
    for kind in SILENT_KINDS:
        plan = FaultPlan(seed=31, silent_corrupt=DETECT_RATE,
                         silent_kind=kind, max_retries=DETECT_RETRIES)
        tr = Transport(plan)
        led = CommLedger()
        t0 = time.time()
        for i in range(rounds):
            delivered, failed = tr.ship(f"detect/{kind}/r{i}", payloads,
                                        led, units=units)
            if failed:
                raise AssertionError(f"{kind}: exhaustion at round {i} "
                                     f"despite {DETECT_RETRIES} retries")
            for j, arr in delivered.items():
                if not np.array_equal(np.asarray(arr), payloads[j]):
                    raise AssertionError(
                        f"{kind}: party {j} delivered a corrupted payload "
                        f"through a VERIFYING wire at round {i}")
        wall = time.time() - t0
        st = tr.stats
        if st.silent_corrupts == 0:
            raise AssertionError(f"{kind}: the plan never corrupted "
                                 f"anything across {rounds} rounds")
        if st.silent_detected != st.silent_corrupts:
            raise AssertionError(
                f"{kind}: {st.silent_corrupts} corruptions but only "
                f"{st.silent_detected} detected — the digest missed some")
        retry_bill = led.by_prefix("retry/")
        if retry_bill != st.units_retried or retry_bill != cells * st.silent_detected:
            raise AssertionError(
                f"{kind}: retry bill {retry_bill} != "
                f"{cells} units x {st.silent_detected} detections")
        entries.append({
            "kind": "detect", "corrupt_kind": kind, "rounds": rounds,
            "messages": rounds * T, "corrupts": st.silent_corrupts,
            "detected": st.silent_detected, "detection_rate": 1.0,
            "retry_units": retry_bill,
        })
        rows.append({
            "bench": BENCH, "method": f"detect-{kind}", "size": rounds * T,
            "cost_mean": 1.0, "cost_std": 0.0, "comm": retry_bill,
            "wall_s": round(wall, 3),
        })

    # end-to-end: a corrupting verified wire is draw-identical to clean,
    # and the build's bill is exactly clean + the retransmissions
    _, ds = _vrlr_stream(21, 8192 if fast else 32768)
    key = jax.random.PRNGKey(17)
    spec = CoresetSpec(task="vrlr", budgets=256, engine="materialized",
                       backend="ref", fault_policy="retry")
    led0 = CommLedger()
    cs0 = CoresetPipeline(ds).build(spec, key=key, ledger=led0)
    tr = Transport(FaultPlan(seed=47, silent_corrupt=0.3, silent_kind="sign",
                             max_retries=DETECT_RETRIES))
    led = CommLedger()
    cs = CoresetPipeline(ds).build(spec, key=key, ledger=led, transport=tr)
    if not (np.array_equal(np.asarray(cs.indices), np.asarray(cs0.indices))
            and np.array_equal(np.asarray(cs.weights),
                               np.asarray(cs0.weights))):
        raise AssertionError("verified wire under corruption drifted from "
                             "the clean build's draw")
    retry_bill = led.by_prefix("retry/")
    if led.total != led0.total + retry_bill:
        raise AssertionError(
            f"corrupted-wire bill {led.total} != clean {led0.total} "
            f"+ retries {retry_bill}")
    if cs.comm_units != cs0.comm_units + tr.stats.units_retried:
        raise AssertionError(
            f"coreset comm_units {cs.comm_units} != clean {cs0.comm_units} "
            f"+ retransmitted {tr.stats.units_retried}")
    entries.append({
        "kind": "detect-e2e", "n": ds.n, "m": 256,
        "corrupts": tr.stats.silent_corrupts,
        "detected": tr.stats.silent_detected,
        "draw_identical": True, "bill": led.total,
        "clean_bill": led0.total, "retry_units": retry_bill,
    })
    return entries, rows


# --------------------------------------------------------------------------
# Experiment 2: poisoned party — undefended skew vs quarantine recovery
# --------------------------------------------------------------------------

def run_quarantine(fast: bool):
    n, m, d, T = POISON_N, 512, 30, 3
    seeds = 2 if fast else 4
    _, ds = _vrlr_stream(3, n, d, T)
    lam = 0.1 * n
    baseline = fit_ridge(ds, full_data_coreset(ds), lam).params

    def rel(cs):
        rep = evaluate(ds, fit_ridge(ds, cs, lam), baseline=baseline)
        r = rep.rel_error
        return float("inf") if not np.isfinite(r) else max(r, 0.0)

    def poisoned(seed):
        # party 0 sign-flips every upload; the receiver never checksums,
        # so the damage reaches the accumulation seam
        return Transport(FaultPlan(seed=7 + seed, silent_corrupt={0: 1.0},
                                   silent_kind="sign"), verify=False)

    def spec(policy):
        return CoresetSpec(task="vrlr", budgets=m, engine="pipelined",
                           backend="ref", block_size=512,
                           fault_policy=policy)

    r_clean, r_undef, r_quar, wall = [], [], [], 0.0
    for s in range(seeds):
        key = jax.random.PRNGKey(100 + s)
        r_clean.append(rel(CoresetPipeline(ds).build(spec("retry"), key=key)))
        try:
            cs_u = CoresetPipeline(ds).build(spec("retry"), key=key,
                                             transport=poisoned(s))
            r_undef.append(rel(cs_u))
        except Exception:
            # a crash is the attack succeeding by another route
            r_undef.append(float("inf"))
        t0 = time.time()
        cs_q = CoresetPipeline(ds).build(spec("quarantine"), key=key,
                                         transport=poisoned(s))
        wall += time.time() - t0
        if cs_q.degraded is None or cs_q.degraded.surviving != (1, 2):
            raise AssertionError(
                f"expected party 0 quarantined, got receipt {cs_q.degraded}")
        if "quarantined for integrity violations" not in cs_q.degraded.reason:
            raise AssertionError(
                f"receipt lacks the integrity reason: {cs_q.degraded.reason!r}")
        r_quar.append(rel(cs_q))

    mean_clean = float(np.mean(r_clean))
    mean_undef = float(np.mean(r_undef))
    mean_quar = float(np.mean(r_quar))
    gate = max(QUALITY_GATE * mean_clean, REL_FLOOR)
    if not mean_undef > gate:
        raise AssertionError(
            f"undefended rel_error {mean_undef:.4f} under a poisoned party "
            f"stays within {gate:.4f} — the attack scenario is toothless")
    if not mean_quar <= gate:
        raise AssertionError(
            f"quarantined rel_error {mean_quar:.4f} exceeds "
            f"max({QUALITY_GATE}x clean {mean_clean:.4f}, {REL_FLOOR}) "
            f"(n={n}, m={m}, {seeds} seeds)")
    entry = {
        "kind": "quarantine", "n": n, "m": m, "seeds": seeds,
        "rel_clean": round(mean_clean, 6),
        "rel_undefended": (None if not np.isfinite(mean_undef)
                           else round(mean_undef, 6)),
        "rel_quarantined": round(mean_quar, 6),
        "undefended_ratio": (None if not np.isfinite(mean_undef)
                             else round(mean_undef / max(mean_clean, 1e-12), 2)),
        "quarantined_ratio": round(mean_quar / max(mean_clean, 1e-12), 3),
    }
    row = {"bench": BENCH, "method": "quarantine-poisoned-party", "size": n,
           "cost_mean": round(mean_quar, 6),
           "cost_std": round(float(np.std(r_quar)), 6),
           "comm": 0, "wall_s": round(wall / seeds, 3)}
    return [entry], [row]


# --------------------------------------------------------------------------
# Experiment 3: checksum overhead on the warm pipelined path
# --------------------------------------------------------------------------

def run_overhead(fast: bool):
    n = 16_384 if fast else 65_536
    m, d, T = 256, 12, 3
    _, ds = _vrlr_stream(9, n, d, T)
    key = jax.random.PRNGKey(5)
    spec = CoresetSpec(task="vrlr", budgets=m, engine="pipelined",
                       backend="ref", block_size=512)

    def build(transport):
        return CoresetPipeline(ds).build(spec, key=key, transport=transport)

    # warm both paths (jit + any lazy setup), pin draw identity
    cs0 = build(None)
    cs1 = build(Transport(FaultPlan.none()))
    if not (np.array_equal(np.asarray(cs0.indices), np.asarray(cs1.indices))
            and np.array_equal(np.asarray(cs0.weights),
                               np.asarray(cs1.weights))):
        raise AssertionError("null verifying transport drifted from the "
                             "transportless build's draw")

    t_bare, t_wire = [], []
    for _ in range(OVERHEAD_REPS):          # interleave to cancel drift
        t0 = time.time()
        build(None)
        t_bare.append(time.time() - t0)
        t0 = time.time()
        build(Transport(FaultPlan.none()))
        t_wire.append(time.time() - t0)
    med_bare = float(np.median(t_bare))
    med_wire = float(np.median(t_wire))
    overhead = med_wire / med_bare - 1.0
    if not overhead <= OVERHEAD_GATE:
        raise AssertionError(
            f"checksummed wire costs {overhead:+.1%} on the warm pipelined "
            f"path (bare {med_bare:.3f}s, wire {med_wire:.3f}s), "
            f"gate is {OVERHEAD_GATE:.0%}")
    entry = {
        "kind": "overhead", "n": n, "m": m, "reps": OVERHEAD_REPS,
        "rows_per_s_bare": round(n / med_bare, 1),
        "rows_per_s_wire": round(n / med_wire, 1),
        "overhead_frac": round(overhead, 4), "draw_identical": True,
    }
    row = {"bench": BENCH, "method": "checksum-overhead", "size": n,
           "cost_mean": round(max(overhead, 0.0), 4), "cost_std": 0.0,
           "comm": 0, "wall_s": round(med_wire, 3)}
    return [entry], [row]


def run(fast: bool = True):
    entries, rows = [], []
    for fn in (run_detect, run_quarantine, run_overhead):
        e, r = fn(fast)
        entries.extend(e)
        rows.extend(r)
    write_rows(BENCH, rows)
    write_bench_json(SECTION, entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
