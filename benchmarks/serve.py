"""Online coreset service benchmark: multi-tenant latency + tree quality.

Two experiments, recorded under the ``serve`` section of BENCH_kernels.json:

* ``workload`` — R tenants stream superchunks into one
  :class:`~repro.serve.service.CoresetService` round-robin, querying as
  they go: p50/p99 insert and query latency, sustained requests/s, and the
  cold/warm split — the FIRST tenant pays plan compilation + jit, later
  tenants hit the shared plan cache (same shapes => warm compiled
  programs).  The ``warm_speedup >= 3`` assertion is the serving-layer
  acceptance gate: if the plan cache stops translating into warm latency,
  this benchmark fails instead of silently recording a regression.

* ``rel_error`` — merge-and-reduce quality: a height-h tree's reduced
  query vs the flat equal-budget batch build on the SAME stream, full-data
  relative error averaged over seeds, for vrlr AND vkmc (vkmc against the
  best-known-centers baseline, the e2e benchmark's basin-roulette
  protection).  The tree runs at its default ``headroom=2`` (nodes keep
  2m rows; only the final query reduce comes down to m — the variance
  control that keeps a height-h tree near the flat build).  Gate: tree
  within 2x of flat (plus a small absolute floor for the regime where
  both errors are ~1e-3 noise).  ``--full`` runs the paper-scale n = 1e5
  acceptance; fast mode is the same experiment at n = 2e4 (CI's smoke).

  PYTHONPATH=src python -m benchmarks.serve --fast
  PYTHONPATH=src python -m benchmarks.run --sections serve
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from repro.core import VFLDataset, build_coreset
from repro.core.solve import evaluate, fit_kmeans, fit_ridge, full_data_coreset
from repro.serve import CoresetService, CoresetTree

BENCH = "serve"
SECTION = "serve"

WARM_SPEEDUP_GATE = 3.0      # warm query must beat the cold query by >= 3x
TREE_VS_FLAT_GATE = 2.0      # tree rel_error within 2x of the flat build
REL_FLOOR = 0.02             # both-tiny regime: absolute floor on the gate


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _chunk_stream(seed, num, rows, d, T, labels):
    """num superchunks of one synthetic stream (cluster + linear structure,
    the e2e generator's recipe) as per-party host arrays."""
    rng = np.random.default_rng(seed)
    k_clusters = 8
    centers = 2.0 * rng.standard_normal((k_clusters, d)).astype(np.float32)
    theta = rng.standard_normal(d).astype(np.float32)
    base, rem = divmod(d, T)
    widths = [base + (1 if j < rem else 0) for j in range(T)]
    chunks = []
    for _ in range(num):
        X = (centers[rng.integers(0, k_clusters, rows)]
             + rng.standard_normal((rows, d)).astype(np.float32))
        y = (X @ theta + 0.1 * rng.standard_normal(rows).astype(np.float32)
             if labels else None)
        parts, start = [], 0
        for w in widths:
            parts.append(X[:, start:start + w])
            start += w
        chunks.append((parts, y))
    return chunks


def _stream_ds(chunks):
    T = len(chunks[0][0])
    parts = [np.concatenate([c[0][j] for c in chunks]) for j in range(T)]
    y = (None if chunks[0][1] is None
         else np.concatenate([c[1] for c in chunks]))
    return VFLDataset(parts, y)


# --------------------------------------------------------------------------
# Experiment 1: multi-tenant workload latency
# --------------------------------------------------------------------------

def run_workload(fast: bool):
    tenants = 3 if fast else 6
    num_chunks = 4 if fast else 8
    rows = 4000 if fast else 12500
    m, d, T = 256, 12, 3

    svc = CoresetService()
    streams = {}
    for i in range(tenants):
        name = f"tenant{i}"
        svc.register(name, task="vrlr", budget=m, seed=i, block_size=2048)
        streams[name] = _chunk_stream(100 + i, num_chunks, rows, d, T, True)

    insert_ms, query_ms = [], []
    cold_query_ms = warm = None
    t_start = time.time()
    requests = 0
    for r in range(num_chunks):
        for i in range(tenants):
            name = f"tenant{i}"
            parts, y = streams[name][r]
            rec = svc.insert(name, parts, y)
            insert_ms.append(rec.latency_s * 1e3)
            requests += 1
            q = svc.query(name, reduce_to=m)
            query_ms.append(q.latency_s * 1e3)
            requests += 1
            if cold_query_ms is None:
                cold_query_ms = q.latency_s * 1e3   # tenant0, round 0: pays jit
    wall = time.time() - t_start

    # warm = typical steady-state query (everything past the first round)
    warm_query_ms = _pct(query_ms[tenants:], 50)
    warm_speedup = cold_query_ms / max(warm_query_ms, 1e-9)
    stats = svc.stats()
    entry = {
        "kind": "workload", "tenants": tenants, "chunks": num_chunks,
        "chunk_rows": rows, "m": m, "d": d, "T": T,
        "insert_p50_ms": round(_pct(insert_ms, 50), 3),
        "insert_p99_ms": round(_pct(insert_ms, 99), 3),
        "query_p50_ms": round(_pct(query_ms, 50), 3),
        "query_p99_ms": round(_pct(query_ms, 99), 3),
        "requests_per_s": round(requests / wall, 2),
        "cold_query_ms": round(cold_query_ms, 3),
        "warm_query_ms": round(warm_query_ms, 3),
        "warm_speedup": round(warm_speedup, 2),
        "plan_hits": stats["plan_hits"], "plan_misses": stats["plan_misses"],
    }
    if not warm_speedup >= WARM_SPEEDUP_GATE:
        raise AssertionError(
            f"warm query {warm_query_ms:.1f}ms is only "
            f"{warm_speedup:.1f}x better than cold {cold_query_ms:.1f}ms "
            f"(gate {WARM_SPEEDUP_GATE}x) — the plan cache stopped paying")
    row = {"bench": BENCH, "method": f"workload-{tenants}t",
           "size": tenants * num_chunks * rows,
           "cost_mean": round(_pct(query_ms, 50), 3),
           "cost_std": round(_pct(query_ms, 99), 3),
           "comm": sum(svc.state(t).ledger.total for t in svc.tenants()),
           "wall_s": round(wall, 2)}
    return entry, row


# --------------------------------------------------------------------------
# Experiment 2: merge-and-reduce quality vs the flat build
# --------------------------------------------------------------------------

def run_rel_error(fast: bool, task: str):
    n = 20_000 if fast else 100_000
    num_chunks = 8
    rows = n // num_chunks
    m = 512 if fast else 2048
    d, T, k = 30, 3, 8
    seeds = 3
    labels = task == "vrlr"
    params = {} if labels else {"k": k}

    chunks = _chunk_stream(3, num_chunks, rows, d, T, labels)
    stream = _stream_ds(chunks)
    lam = 0.1 * n

    if labels:
        baseline = fit_ridge(stream, full_data_coreset(stream), lam).params
    else:
        baseline = fit_kmeans(stream, full_data_coreset(stream), k,
                              key=jax.random.PRNGKey(99), restarts=5,
                              backend="ref").params

    def rel(cs, seed):
        if labels:
            rep = evaluate(stream, fit_ridge(stream, cs, lam),
                           baseline=baseline)
            return max(rep.rel_error, 0.0)
        # k-means: the coreset fit itself is basin roulette (the weighted
        # objective that picks the best restart can mis-rank on full data),
        # so take the best of two independent fit seedings — this measures
        # CORESET quality, not Lloyd's luck, and applies equally to the
        # tree and the flat build.  Baseline = best-known centers (e2e).
        rels = []
        for t in range(2):
            fit = fit_kmeans(stream, cs, k,
                             key=jax.random.PRNGKey(1000 + seed + 7919 * t),
                             restarts=5, backend="ref")
            rep0 = evaluate(stream, fit, baseline=baseline)
            best = baseline if rep0.rel_error >= 0 else fit.params
            rels.append(max(evaluate(stream, fit, baseline=best).rel_error,
                            0.0))
        return min(rels)

    r_tree, r_flat, build_s = [], [], 0.0
    for s in range(seeds):
        tree = CoresetTree(task, m, key=jax.random.PRNGKey(s),
                           block_size=4096, params=params)
        t0 = time.time()
        for parts, y in chunks:
            tree.insert(parts, y)
        q = tree.query(reduce_to=m)
        build_s += time.time() - t0
        r_tree.append(rel(q.coreset(), s))
        flat = build_coreset(task, stream, m, key=jax.random.PRNGKey(50 + s),
                             backend="ref", **params)
        r_flat.append(rel(flat, s))
    mean_tree, mean_flat = float(np.mean(r_tree)), float(np.mean(r_flat))
    ratio = mean_tree / max(mean_flat, 1e-12)

    gate = max(TREE_VS_FLAT_GATE * mean_flat, REL_FLOOR)
    if not mean_tree <= gate:
        raise AssertionError(
            f"{task}: tree rel_error {mean_tree:.4f} exceeds "
            f"max({TREE_VS_FLAT_GATE}x flat {mean_flat:.4f}, {REL_FLOOR}) "
            f"(n={n}, m={m}, {num_chunks} chunks, {seeds} seeds)")
    entry = {
        "kind": "rel_error", "task": task, "n": n, "m": m,
        "chunks": num_chunks, "seeds": seeds,
        "rel_tree": round(mean_tree, 6), "rel_flat": round(mean_flat, 6),
        "ratio_vs_flat": round(ratio, 3),
        "tree_build_s": round(build_s / seeds, 3),
    }
    row = {"bench": BENCH, "method": f"tree-vs-flat-{task}", "size": n,
           "cost_mean": round(mean_tree, 6),
           "cost_std": round(float(np.std(r_tree)), 6),
           "comm": 0, "wall_s": round(build_s / seeds, 3)}
    return entry, row


def run(fast: bool = True):
    entries, rows = [], []
    e, r = run_workload(fast)
    entries.append(e)
    rows.append(r)
    for task in ("vrlr", "vkmc"):
        e, r = run_rel_error(fast, task)
        entries.append(e)
        rows.append(r)
    write_rows(BENCH, rows)
    write_bench_json(SECTION, entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
