"""Streaming vs materialized coreset construction: rows/sec + peak live bytes.

The materialized pipeline puts the full (T, n, s) stacked design and the
(T, n) score matrix on device; the streaming pipeline
(``build_coreset_streaming``) keeps the dataset host-resident (numpy-backed
``VFLDataset``) and holds ONE (T, bs, s) block at a time — or, pipelined,
one double-buffered (C, T, bs, s) superchunk — so peak live device bytes
are O(chunk_blocks * block_size * d) while the materialized path's are
O(n * d).  Both are *measured*, not asserted: the dataset is generated in
host numpy, and a ``jax.live_arrays()`` census (deduped by underlying
buffer, so aliased/donated slots count once) runs after every chunk step
(the ``probe`` hook) and around the materialized build — the streamed
analogue of ``fused_lloyd``'s structural passes-over-X check.

Rows land in BENCH_kernels.json under two sections:

* ``streaming`` — the block-at-a-time engine (PR 3's dispatch granularity,
  kept as the draw-identity oracle): ``{path, n, d, T, m, block_size,
  rows_per_s, peak_live_bytes, data_passes}``.
* ``streaming_pipelined`` — the pipelined engine (double-buffered prefetch
  + scan-fused superchunks + grouped one-dispatch redraw) over a
  block_size x chunk_blocks sweep plus a prefetch on/off ablation; each
  entry also records ``chunk_bytes`` (the C-block superchunk yardstick the
  peak is judged against) and ``speedup_vs_streaming`` against the
  same-block-size ``streaming`` row from the SAME run/backend.

Every pipelined construction is asserted draw-identical to the
block-at-a-time one for the same key before its row is recorded — the
benchmark doubles as the end-to-end identity smoke (CI runs it in
``--fast`` mode at the n = 50k cap).  ``--full`` runs n = 10^6, the regime
the materialized path cannot enter on a fixed device budget.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from repro.core import CommLedger, VFLDataset, build_coreset, build_coreset_streaming
from repro.core.plan import live_bytes  # productionized census (PR 9)

BENCH = "streaming"
BENCH_PIPE = "streaming_pipelined"


def _host_dataset(n: int, d: int, T: int):
    """Numpy-backed VFLDataset — nothing lands on device until a block does."""
    rng = np.random.default_rng(7)
    X = rng.standard_normal((n, d), dtype=np.float32)
    theta = rng.standard_normal((d,), dtype=np.float32)
    y = X @ theta + 0.1 * rng.standard_normal(n, dtype=np.float32)
    parts, start = [], 0
    base, rem = divmod(d, T)
    for j in range(T):
        w = base + (1 if j < rem else 0)
        parts.append(X[:, start:start + w])
        start += w
    return VFLDataset(parts, y)


class _Peak:
    """Running max of the live-bytes census (the streaming probe)."""

    def __init__(self):
        self.peak = 0

    def __call__(self):
        self.peak = max(self.peak, live_bytes())


def _run_streaming(ds, m: int, block_size: int, chunk_blocks: int = 1,
                   prefetch: bool = False):
    peak = _Peak()
    led = CommLedger()
    t0 = time.time()
    cs = build_coreset_streaming("vrlr", ds, m, key=jax.random.PRNGKey(0),
                                 backend="ref", block_size=block_size,
                                 chunk_blocks=chunk_blocks, prefetch=prefetch,
                                 ledger=led, probe=peak)
    jax.block_until_ready(cs.weights)
    wall = time.time() - t0
    peak()
    return cs, wall, peak.peak, led.total


def _run_materialized(ds_host, m: int):
    """The flat pipeline on a device-resident copy of the same data."""
    ds = VFLDataset([jnp.asarray(p) for p in ds_host.parts],
                    jnp.asarray(ds_host.y))
    led = CommLedger()
    t0 = time.time()
    cs = build_coreset("vrlr", ds, m, key=jax.random.PRNGKey(0),
                       backend="ref", ledger=led)
    jax.block_until_ready(cs.weights)
    wall = time.time() - t0
    peak = live_bytes()          # scores + stacked design are still live here
    del ds
    return cs, wall, peak, led.total


def _assert_draw_identical(cs_ref, cs_new, label: str):
    """The pipelined engine must reproduce the block-at-a-time draws
    exactly — this makes the benchmark double as the identity smoke."""
    if not (np.array_equal(np.asarray(cs_ref.indices), np.asarray(cs_new.indices))
            and np.array_equal(np.asarray(cs_ref.weights),
                               np.asarray(cs_new.weights))):
        raise AssertionError(
            f"pipelined draws diverged from the streamed oracle at {label}"
        )


def run(fast: bool = True):
    n = 50_000 if fast else 1_000_000
    d, T, m = 30, 3, 512
    block_sizes = [4096, 16384, 65536]
    chunk_sweeps = [4, 16]
    ds_host = _host_dataset(n, d, T)

    rows, entries, pipe_entries = [], [], []
    base_rows_per_s = {}                    # block_size -> streaming rows/s

    def block_bytes(bsz: int) -> int:
        # the O(block_size * d) yardstick: one labeled (T, bs, s) block
        return int(T * bsz * (d // T + 1) * 4)

    def record(path, wall, peak, comm, block_size=None, passes=None,
               chunk_blocks=None, prefetch=None):
        label = path if block_size is None else f"{path}-b{block_size}"
        if chunk_blocks is not None:
            label += f"-c{chunk_blocks}" + ("" if prefetch else "-noprefetch")
        rows.append({"bench": BENCH, "method": label, "size": n,
                     "cost_mean": round(peak / 1e6, 3), "cost_std": 0.0,
                     "comm": comm, "wall_s": round(wall, 4)})
        entry = {"path": label, "n": n, "d": d, "T": T, "m": m,
                 "rows_per_s": round(n / max(wall, 1e-9), 1),
                 "peak_live_bytes": int(peak)}
        if block_size is not None:
            entry["block_size"] = block_size
            entry["block_bytes"] = block_bytes(block_size)
        if passes is not None:
            entry["data_passes"] = passes
        if chunk_blocks is None:
            entries.append(entry)
        else:
            entry["chunk_blocks"] = chunk_blocks
            entry["prefetch"] = bool(prefetch)
            # the superchunk yardstick: peak should stay within ~2.5x of it
            # (two double-buffered slots + one live compute residency);
            # chunk_blocks clamps to the block count, so the yardstick does too
            eff_chunk = min(chunk_blocks, -(-n // block_size))
            entry["chunk_bytes"] = eff_chunk * block_bytes(block_size)
            base = base_rows_per_s.get(block_size)
            if base:
                entry["speedup_vs_streaming"] = round(
                    entry["rows_per_s"] / base, 2)
            pipe_entries.append(entry)
        return entry

    # materialized reference (device-resident flat pipeline)
    _, wall, peak, comm = _run_materialized(ds_host, m)
    record("materialized", wall, peak, comm)

    # block-at-a-time streaming sweep (vrlr ref = 2 full passes: Gram+masses)
    ref_cs = {}
    for bsz in block_sizes:
        if bsz >= n:
            continue
        cs, wall, peak, comm = _run_streaming(ds_host, m, bsz)
        entry = record("streaming", wall, peak, comm, block_size=bsz, passes=2)
        base_rows_per_s[bsz] = entry["rows_per_s"]
        ref_cs[bsz] = cs

    # pipelined engine: block_size x chunk_blocks sweep, all draw-checked.
    # Each config runs twice — the first (cold) wall includes the one-time
    # jit compiles of the superchunk scan/redraw programs, the second (warm)
    # is the steady-state the engine sustains (the time_us warmup
    # convention); rows_per_s reports warm, rows_per_s_cold keeps the cold
    # number honest.
    def pipelined(bsz, C, prefetch):
        cs, wall_cold, peak, comm = _run_streaming(
            ds_host, m, bsz, chunk_blocks=C, prefetch=prefetch)
        tag = f"b{bsz}-c{C}" + ("" if prefetch else "-noprefetch")
        _assert_draw_identical(ref_cs[bsz], cs, tag)
        cs, wall, peak2, comm = _run_streaming(
            ds_host, m, bsz, chunk_blocks=C, prefetch=prefetch)
        _assert_draw_identical(ref_cs[bsz], cs, tag + "-warm")
        entry = record("pipelined", wall, max(peak, peak2), comm,
                       block_size=bsz, passes=2, chunk_blocks=C,
                       prefetch=prefetch)
        entry["rows_per_s_cold"] = round(n / max(wall_cold, 1e-9), 1)

    for bsz in block_sizes:
        if bsz >= n:
            continue
        for C in chunk_sweeps:
            pipelined(bsz, C, prefetch=True)

    # prefetch ablation at the smallest block size (dispatch-bound regime)
    bsz = block_sizes[0]
    if bsz < n:
        pipelined(bsz, chunk_sweeps[-1], prefetch=False)

    write_rows(BENCH, rows)
    write_bench_json(BENCH, entries)
    write_bench_json(BENCH_PIPE, pipe_entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
