"""Streaming vs materialized coreset construction: rows/sec + peak live bytes.

The materialized pipeline puts the full (T, n, s) stacked design and the
(T, n) score matrix on device; the streaming pipeline
(``build_coreset_streaming``) keeps the dataset host-resident (numpy-backed
``VFLDataset``) and holds ONE (T, bs, s) block at a time, so peak live
device bytes are O(block_size * d) while the materialized path's are
O(n * d).  Both are *measured*, not asserted: the dataset is generated in
host numpy, and a ``jax.live_arrays()`` census runs after every block step
(the ``probe`` hook) and around the materialized build — the streamed
analogue of ``fused_lloyd``'s structural passes-over-X check.

Rows land in BENCH_kernels.json under the ``streaming`` section:
``{path, n, d, T, m, block_size, rows_per_s, peak_live_bytes, data_passes}``.
In ``--fast`` mode n = 50k (the CI smoke cap); ``--full`` runs n = 10^6,
where the streamed peak stays flat across n while the materialized peak
scales with it.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from repro.core import CommLedger, VFLDataset, build_coreset, build_coreset_streaming

BENCH = "streaming"


def live_bytes() -> int:
    """Total bytes of live device arrays right now."""
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.live_arrays())


def _host_dataset(n: int, d: int, T: int):
    """Numpy-backed VFLDataset — nothing lands on device until a block does."""
    rng = np.random.default_rng(7)
    X = rng.standard_normal((n, d), dtype=np.float32)
    theta = rng.standard_normal((d,), dtype=np.float32)
    y = X @ theta + 0.1 * rng.standard_normal(n, dtype=np.float32)
    parts, start = [], 0
    base, rem = divmod(d, T)
    for j in range(T):
        w = base + (1 if j < rem else 0)
        parts.append(X[:, start:start + w])
        start += w
    return VFLDataset(parts, y)


class _Peak:
    """Running max of the live-bytes census (the streaming probe)."""

    def __init__(self):
        self.peak = 0

    def __call__(self):
        self.peak = max(self.peak, live_bytes())


def _run_streaming(ds, m: int, block_size: int):
    peak = _Peak()
    led = CommLedger()
    t0 = time.time()
    cs = build_coreset_streaming("vrlr", ds, m, key=jax.random.PRNGKey(0),
                                 backend="ref", block_size=block_size,
                                 ledger=led, probe=peak)
    jax.block_until_ready(cs.weights)
    wall = time.time() - t0
    peak()
    return cs, wall, peak.peak, led.total


def _run_materialized(ds_host, m: int):
    """The flat pipeline on a device-resident copy of the same data."""
    ds = VFLDataset([jnp.asarray(p) for p in ds_host.parts],
                    jnp.asarray(ds_host.y))
    led = CommLedger()
    t0 = time.time()
    cs = build_coreset("vrlr", ds, m, key=jax.random.PRNGKey(0),
                       backend="ref", ledger=led)
    jax.block_until_ready(cs.weights)
    wall = time.time() - t0
    peak = live_bytes()          # scores + stacked design are still live here
    del ds
    return cs, wall, peak, led.total


def run(fast: bool = True):
    n = 50_000 if fast else 1_000_000
    d, T, m = 30, 3, 512
    block_sizes = [4096, 16384, 65536]
    ds_host = _host_dataset(n, d, T)

    rows, entries = [], []

    def record(path, wall, peak, comm, block_size=None, passes=None):
        label = path if block_size is None else f"{path}-b{block_size}"
        rows.append({"bench": BENCH, "method": label, "size": n,
                     "cost_mean": round(peak / 1e6, 3), "cost_std": 0.0,
                     "comm": comm, "wall_s": round(wall, 4)})
        entry = {"path": label, "n": n, "d": d, "T": T, "m": m,
                 "rows_per_s": round(n / max(wall, 1e-9), 1),
                 "peak_live_bytes": int(peak)}
        if block_size is not None:
            entry["block_size"] = block_size
            # the O(block_size * d) yardstick the peak is judged against:
            # one labeled (T, bs, s) block + the (T, s, s)/(T, nb) state
            entry["block_bytes"] = int(T * block_size * (d // T + 1) * 4)
        if passes is not None:
            entry["data_passes"] = passes
        entries.append(entry)

    # materialized reference (device-resident flat pipeline)
    _, wall, peak, comm = _run_materialized(ds_host, m)
    record("materialized", wall, peak, comm)

    # streaming at a block-size sweep (vrlr ref = 2 full passes: Gram + masses)
    for bsz in block_sizes:
        if bsz >= n:
            continue
        cs, wall, peak, comm = _run_streaming(ds_host, m, bsz)
        record("streaming", wall, peak, comm, block_size=bsz, passes=2)

    write_rows(BENCH, rows)
    write_bench_json(BENCH, entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
