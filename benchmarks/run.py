"""Benchmark aggregator — one module per paper table/figure.

Prints the harness CSV ``name,us_per_call,derived`` (one line per method
cell; us_per_call = method wall time; derived = "cost=<avg loss>
comm=<units>") and writes the full per-bench CSVs to
benchmarks/artifacts/.

  PYTHONPATH=src python -m benchmarks.run           # fast (CPU-budget) sizes
  PYTHONPATH=src python -m benchmarks.run --full    # paper-scale n / repeats
  PYTHONPATH=src python -m benchmarks.run --sections kernel_micro,streaming
  PYTHONPATH=src python -m benchmarks.run --list    # show section names
"""

from __future__ import annotations

import argparse
import os
import sys
import time

# The paper benchmarks measure LOSS and COMMUNICATION, not kernel wall time;
# on this CPU container the Pallas kernels run in interpret mode (~20x slower
# than compiled jnp, semantically identical — tests/test_kernels.py proves
# it), so route the hot loops to the jnp references.  kernel_micro and
# fused_lloyd resolve the execution mode themselves via
# repro.core.api.resolve_backend: compiled-kernel timings on TPU/GPU,
# jnp-ref (+ structural census) on CPU — interpret-mode wall numbers are
# only recorded behind their explicit --interpret flag, clearly labeled.
os.environ.setdefault("REPRO_NO_PALLAS", "1")

MODULES = [
    "vrlr_main",        # Table 1 left / Fig 2
    "vkmc_main",        # Table 1 right / Fig 3
    "parties",          # Fig 4/5 (T=5)
    "regularizers",     # Fig 6-8 (linear / lasso / elastic)
    "centers",          # Fig 9 (k=5)
    "second_dataset",   # Fig 10/11 (KC-House profile)
    "kernel_micro",     # Pallas kernel us/call
    "fused_lloyd",      # fused vs seed Lloyd step: passes-over-X + us/step
    "streaming",        # streaming vs materialized: rows/sec + peak bytes
    "e2e",              # spec-build + downstream fit: wall time + rel error
    "serve",            # online service: tenant latency + tree-vs-flat quality
    "selector_step",    # beyond-paper: LLM coreset batch selection
    "assumption_sweep",  # beyond-paper: Assumption 4.1/5.1 violation sweep
    "chaos",            # fault injection: retry billing + degrade + resume
    "integrity",        # silent corruption: detection + quarantine + overhead
    "overload",         # hostile tenant mix: shed/breaker/failover gates
    "compression",      # codec wire: raw identity + CRC/retry bits + tradeoff
]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--sections", "--only", dest="sections", default=None,
                    help="comma-separated subset of bench modules to run "
                         f"(known: {','.join(MODULES)})")
    ap.add_argument("--list", action="store_true",
                    help="print the section names and exit")
    ap.add_argument("--strict", action="store_true",
                    help="re-raise the first section failure instead of "
                         "continuing (non-zero exit with a traceback; used "
                         "by the CI gate steps)")
    args = ap.parse_args()
    if args.list:
        print("\n".join(MODULES))
        return 0
    mods = args.sections.split(",") if args.sections else MODULES
    unknown = [m for m in mods if m not in MODULES]
    if unknown:
        ap.error(f"unknown sections {unknown}; known: {','.join(MODULES)}")

    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run(fast=not args.full)
            for r in rows:
                label = f"{r['bench']}/{r['method']}({r['size']})"
                us = r["wall_s"] * 1e6
                derived = f"cost={r['cost_mean']:.4g} comm={r['comm']}"
                print(f"{label},{us:.0f},{derived}")
        except Exception as e:  # keep the suite going; report at the end
            if args.strict:
                raise
            # failures go to stderr ONLY — stdout stays parseable CSV
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            import traceback
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} finished in {time.time()-t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
