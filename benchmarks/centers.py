"""Paper Appendix A.3 (Figure 9): VKMC with k=5 centers."""

from __future__ import annotations

from benchmarks.vkmc_main import run as run_vkmc

BENCH = "centers_k5"


def run(fast: bool = True):
    return run_vkmc(fast, k=5, bench=BENCH)


if __name__ == "__main__":
    for r in run():
        print(r)
