"""Chaos benchmark: the fault seam under injected party failures.

Three experiments, recorded under the ``chaos`` section of
BENCH_kernels.json:

* ``sweep`` — drop-rate p in {0, 0.05, 0.2} x fault_policy in
  {retry, degrade}: every build must COMPLETE, and the composed bill must
  stay exact — base tags bill exactly the fault-free schedule (asserted to
  the unit), retransmissions live under ``retry/`` tags, and at the
  heaviest cell (p=0.2, retry) the total ledger stays within the
  ``(1 + p * max_retries)x`` envelope of the fault-free bill.  p=0 is the
  null-plan identity: the bill equals the transportless build's exactly.
* ``degrade`` — one party certainly dead at round 1 under
  ``fault_policy="degrade"``: the build continues over the survivors and
  the downstream ridge fit's rel_error stays within 3x of the all-party
  build at n=2e4 (plus a small absolute floor for the both-tiny regime).
* ``resume`` — a pipelined build killed mid-scan (probe bomb) and a tree
  insert killed the same way: after the crash the tree has rolled back
  (ledger + counters), and the checkpointed retry finishes DRAW-IDENTICAL
  (indices, weights, ledger total) to a never-interrupted run.

  PYTHONPATH=src python -m benchmarks.chaos --fast
  PYTHONPATH=src python -m benchmarks.run --sections chaos --strict
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from benchmarks.serve import _chunk_stream, _stream_ds
from repro.core import (
    CommLedger,
    CoresetPipeline,
    CoresetSpec,
    FaultPlan,
    StreamCheckpoint,
    Transport,
)
from repro.core.solve import evaluate, fit_ridge, full_data_coreset
from repro.serve import CoresetTree

BENCH = "chaos"
SECTION = "chaos"

DROP_RATES = (0.0, 0.05, 0.2)
POLICIES = ("retry", "degrade")
MAX_RETRIES = 6              # 0.2^7 ~ 1e-5 exhaustion odds per message
OVERHEAD_GATE_P = 0.2        # the envelope is asserted at the heaviest cell
DEGRADE_GATE = 3.0           # degraded rel_error within 3x of all-party
REL_FLOOR = 0.02             # both-tiny regime: absolute floor on the gate
DEGRADE_N = 20_000           # the acceptance criterion's n


def _vrlr_stream(seed, n, d=12, T=3, num_chunks=4):
    chunks = _chunk_stream(seed, num_chunks, n // num_chunks, d, T, True)
    return chunks, _stream_ds(chunks)


# --------------------------------------------------------------------------
# Experiment 1: drop-rate x policy sweep with exact-billing gates
# --------------------------------------------------------------------------

def run_sweep(fast: bool):
    n = 8192 if fast else 32768
    m, d, T = 256, 12, 3
    _, ds = _vrlr_stream(21, n, d, T)
    key = jax.random.PRNGKey(17)

    # the fault-free reference bill (transportless build, same spec/key)
    led0 = CommLedger()
    spec0 = CoresetSpec(task="vrlr", budgets=m, engine="materialized",
                        backend="ref")
    cs0 = CoresetPipeline(ds).build(spec0, key=key, ledger=led0)
    base_bill = led0.total

    entries, rows = [], []
    for policy in POLICIES:
        for p in DROP_RATES:
            plan = FaultPlan(seed=1000 + int(p * 100), drop=p,
                             max_retries=MAX_RETRIES)
            tr = Transport(plan)
            led = CommLedger()
            spec = CoresetSpec(task="vrlr", budgets=m, engine="materialized",
                               backend="ref", fault_policy=policy)
            t0 = time.time()
            cs = CoresetPipeline(ds).build(spec, key=key, ledger=led,
                                           transport=tr)
            wall = time.time() - t0

            retry_units = led.by_prefix("retry/")
            # exact billing: base tags are ALWAYS the fault-free schedule
            if cs.degraded is None:
                if led.total - retry_units != base_bill:
                    raise AssertionError(
                        f"{policy}@p={p}: base-tag bill "
                        f"{led.total - retry_units} != fault-free {base_bill}")
                if not np.array_equal(np.asarray(cs.indices),
                                      np.asarray(cs0.indices)):
                    raise AssertionError(
                        f"{policy}@p={p}: draws drifted from the "
                        f"fault-free build despite no party dropping")
            if p == 0.0 and (retry_units != 0 or led.total != base_bill):
                raise AssertionError(
                    f"{policy}@p=0: null plan billed {led.total} "
                    f"(retries {retry_units}), fault-free is {base_bill}")
            if policy == "retry" and p == OVERHEAD_GATE_P:
                envelope = (1.0 + p * MAX_RETRIES) * base_bill
                if not led.total <= envelope:
                    raise AssertionError(
                        f"retry@p={p}: bill {led.total} exceeds the "
                        f"(1 + p*max_retries) envelope {envelope:.0f} "
                        f"of fault-free {base_bill}")
            entries.append({
                "kind": "sweep", "policy": policy, "drop": p, "n": n, "m": m,
                "bill": led.total, "base_bill": base_bill,
                "retry_units": retry_units, "retries": tr.stats.retries,
                "drops": tr.stats.drops, "timeouts": tr.stats.timeouts,
                "corrupts": tr.stats.corrupts,
                "degraded": cs.degraded is not None,
                "sim_time_s": round(tr.stats.sim_time_s, 4),
            })
            rows.append({
                "bench": BENCH, "method": f"{policy}-p{p}", "size": n,
                "cost_mean": round(led.total / max(base_bill, 1), 4),
                "cost_std": 0.0, "comm": led.total,
                "wall_s": round(wall, 3),
            })
    return entries, rows


# --------------------------------------------------------------------------
# Experiment 2: degraded build quality vs the all-party build
# --------------------------------------------------------------------------

def run_degrade(fast: bool):
    n, m, d, T = DEGRADE_N, 512, 30, 3
    seeds = 2 if fast else 4
    _, ds = _vrlr_stream(3, n, d, T)
    lam = 0.1 * n
    baseline = fit_ridge(ds, full_data_coreset(ds), lam).params

    def rel(cs):
        rep = evaluate(ds, fit_ridge(ds, cs, lam), baseline=baseline)
        return max(rep.rel_error, 0.0)

    r_full, r_degr, wall = [], [], 0.0
    for s in range(seeds):
        key = jax.random.PRNGKey(100 + s)
        spec_full = CoresetSpec(task="vrlr", budgets=m, engine="materialized",
                                backend="ref")
        r_full.append(rel(CoresetPipeline(ds).build(spec_full, key=key)))
        # party 0 certainly dead at round 1; labels (party T-1) survive
        tr = Transport(FaultPlan(seed=7 + s, drop={0: 1.0}, max_retries=2))
        spec_d = CoresetSpec(task="vrlr", budgets=m, engine="materialized",
                             backend="ref", fault_policy="degrade")
        t0 = time.time()
        cs_d = CoresetPipeline(ds).build(spec_d, key=key, transport=tr)
        wall += time.time() - t0
        if cs_d.degraded is None or cs_d.degraded.surviving != (1, 2):
            raise AssertionError(
                f"expected party 0 dropped, got receipt {cs_d.degraded}")
        r_degr.append(rel(cs_d))
    mean_full, mean_degr = float(np.mean(r_full)), float(np.mean(r_degr))
    gate = max(DEGRADE_GATE * mean_full, REL_FLOOR)
    if not mean_degr <= gate:
        raise AssertionError(
            f"degraded rel_error {mean_degr:.4f} exceeds "
            f"max({DEGRADE_GATE}x all-party {mean_full:.4f}, {REL_FLOOR}) "
            f"(n={n}, m={m}, {seeds} seeds)")
    entry = {
        "kind": "degrade", "n": n, "m": m, "seeds": seeds,
        "rel_full": round(mean_full, 6), "rel_degraded": round(mean_degr, 6),
        "ratio": round(mean_degr / max(mean_full, 1e-12), 3),
        "bound_factor": T / (T - 1),
    }
    row = {"bench": BENCH, "method": "degrade-one-party", "size": n,
           "cost_mean": round(mean_degr, 6),
           "cost_std": round(float(np.std(r_degr)), 6),
           "comm": 0, "wall_s": round(wall / seeds, 3)}
    return [entry], [row]


# --------------------------------------------------------------------------
# Experiment 3: mid-insert crash + checkpointed resume, draw-identical
# --------------------------------------------------------------------------

class _Bomb:
    """A probe that raises on its k-th superchunk step — the crash."""

    def __init__(self, at: int) -> None:
        self.at = at
        self.calls = 0

    def __call__(self) -> None:
        self.calls += 1
        if self.calls == self.at:
            raise RuntimeError("chaos: killed mid-scan")


def run_resume(fast: bool):
    n = 4096 if fast else 16384
    m, d, T = 128, 12, 3
    chunks, _ = _vrlr_stream(5, n, d, T, num_chunks=4)
    tree_kw = dict(key=jax.random.PRNGKey(0), backend="ref",
                   block_size=256, chunk_blocks=2)

    t_ref = CoresetTree("vrlr", m, **tree_kw)
    ck = StreamCheckpoint()
    t_cr = CoresetTree("vrlr", m, checkpoint=ck, **tree_kw)
    t0 = time.time()
    crashes = 0
    for i, (parts, y) in enumerate(chunks):
        t_ref.insert(parts, y)
        if i == 2:                        # kill chunk 2's leaf build mid-scan
            pre_total = t_cr.ledger.total
            pre_chunks = t_cr.num_chunks
            import repro.serve.tree as treemod
            orig = treemod.CoresetPipeline.build
            bomb = _Bomb(at=2)

            def crashing(self, *a, **kw):
                kw["probe"] = bomb
                return orig(self, *a, **kw)

            treemod.CoresetPipeline.build = crashing
            try:
                t_cr.insert(parts, y)
                raise AssertionError("the bomb never went off")
            except RuntimeError:
                crashes += 1
            finally:
                treemod.CoresetPipeline.build = orig
            if (t_cr.ledger.total, t_cr.num_chunks) != (pre_total, pre_chunks):
                raise AssertionError(
                    "crashed insert left state behind: ledger "
                    f"{pre_total}->{t_cr.ledger.total}, chunks "
                    f"{pre_chunks}->{t_cr.num_chunks}")
        t_cr.insert(parts, y)             # the retry (resumes from ckpt)
    wall = time.time() - t0

    q_ref, q_cr = t_ref.query(), t_cr.query()
    if not (np.array_equal(q_ref.indices, q_cr.indices)
            and np.array_equal(q_ref.weights, q_cr.weights)
            and t_ref.ledger.total == t_cr.ledger.total):
        raise AssertionError(
            "crash+resume diverged from the uninterrupted stream: "
            f"m {q_ref.m} vs {q_cr.m}, bill {t_ref.ledger.total} vs "
            f"{t_cr.ledger.total}")
    if ck.resumes < 1:
        raise AssertionError("the retried insert never loaded a checkpoint")
    entry = {
        "kind": "resume", "n": n, "m": m, "chunks": len(chunks),
        "crashes": crashes, "ckpt_saves": ck.saves,
        "ckpt_resumes": ck.resumes, "draw_identical": True,
        "bill": t_cr.ledger.total,
    }
    row = {"bench": BENCH, "method": "crash-resume", "size": n,
           "cost_mean": 0.0, "cost_std": 0.0,
           "comm": t_cr.ledger.total, "wall_s": round(wall, 3)}
    return [entry], [row]


def run(fast: bool = True):
    entries, rows = [], []
    for fn in (run_sweep, run_degrade, run_resume):
        e, r = fn(fast)
        entries.extend(e)
        rows.extend(r)
    write_rows(BENCH, rows)
    write_bench_json(SECTION, entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
